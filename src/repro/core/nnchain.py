"""Nearest-neighbor-chain merge engine — exact agglomeration in O(n²) total
work (DESIGN.md §11).

The Lance-Williams loop in :mod:`repro.core.engine` pays a full matrix
pass per merge — O(n³) work for a full run even with compaction shaving
the constant.  For the **reducible** linkage methods
(:data:`REDUCIBLE_METHODS`: single, complete, average, weighted, ward)
the classical NN-chain algorithm (Murtagh) reaches the *same dendrogram*
in O(n²) total work: grow a chain ``a → NN(a) → NN(NN(a)) → …`` of
strictly decreasing distances until two clusters are mutual nearest
neighbors, merge them, and continue from the surviving chain.
Reducibility — ``d(i,j) ≤ d(i,k), d(j,k)  ⇒  d(i∪j, k) ≥ d(i,j)`` —
guarantees the remaining chain stays a valid NN chain after the merge,
so every cluster is pushed O(1) times amortized and each push costs one
O(n) row scan.

Merges are emitted in **chain order**, not by global height; for
reducible methods a stable sort by height
(:func:`repro.core.dendrogram.canonical_order`) rewrites the list into
exactly the sequence the LW loop produces — same ``(i, j)`` slot pairs
(a cluster's slot is the minimum leaf index of its members, in both
engines) and the same heights to float tolerance (each height is the
same recurrence DAG regardless of merge order, but XLA fuses/contracts
the arithmetic differently across the two programs — last-ulp
differences, same phenomenon as the batched engines' padded-shape
nonidentity).  Equivalence is asserted
against :mod:`repro.core.engine` goldens in ``tests/test_nnchain.py``
and re-checked at benchmark scale in ``benchmarks/bench_nnchain.py``.

Two compositions share the one chain loop:

* **dense** (:func:`nn_chain`) — the ``(n, n)`` matrix in the garbage
  representation; a merge rewrites row *and* column ``i`` with two
  O(n) ``dynamic_update_slice`` passes (never a full-matrix select —
  that is the LW engine's O(n²) step this engine exists to avoid).
* **points / matrix-free** (:func:`nn_chain_from_points`) — never
  materializes the matrix.  Cluster state is an O(n·d + n) **geometric
  summary** ``(w, u, size)`` per slot; candidate distances are produced
  row-by-row as ``scale · ‖w_top − w_k‖² + u_top + u_k``, either as one
  jnp pass or tile-by-tile through the Pallas row-vs-points kernel
  (:func:`repro.kernels.pairwise.row_sq_euclidean_pallas`).  Exact for
  the methods whose LW distance is a function of that summary
  (:data:`POINTS_METHODS`, all on **squared-Euclidean** input):

  - ``ward``:    ``d(A,B) = 2·n_A n_B/(n_A+n_B) · ‖c_A − c_B‖²``
                 (Wishart form; ``w`` = centroid, ``u ≡ 0``),
  - ``average``: ``d(A,B) = ‖c_A − c_B‖² + v_A + v_B``
                 (``w`` = centroid, ``u`` = mean within-cluster scatter),
  - ``weighted``: same form over the WPGMA midpoint
                 ``w_{A∪B} = (w_A + w_B)/2``,
                 ``u_{A∪B} = (u_A + u_B)/2 + ‖w_A − w_B‖²/4``.

  ``single``/``complete`` distances are min/max pair statistics with no
  O(d) sufficient summary — they stay on the dense path (DESIGN.md §11).

Early termination (``stop_at_k`` / ``distance_threshold``) is *post-hoc*
here: the full agglomeration is O(n²) anyway, so
:func:`repro.core.api.cluster` runs it, canonicalizes, and truncates the
height-sorted prefix — the same result the LW loop's early exit returns.

**Batched compositions** (:func:`nn_chain_batched`,
:func:`nn_chain_batched_from_points`, DESIGN.md §11): the same chain
loop ``vmap``-ed over a shape bucket.  The per-lane merge target
becomes a *traced* scalar (``max(n_real − 1, 0)``) instead of the
static trip count, and the ``while_loop`` vmap batching rule then
freezes finished lanes exactly the way the LW ``distance_threshold``
loop does — a lane whose chain has emitted its last merge (or a dead
padded lane, target 0) stops contributing state updates while the
slower lanes run on.  Padded slots are born dead and masked at read,
so each lane's merge sequence is the serial engine's (heights to the
usual padded-shape float tolerance).  The batched entry points keep the
``(Db, n_real, threshold)`` operand convention of the batched LW
engines so the service AOT cache compiles them interchangeably; the
threshold operand is accepted and ignored — early stop stays post-hoc
(:func:`repro.core.dendrogram.truncate_canonical`).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import LWResult, _first_where, symmetrize
from repro.core.linkage import METHODS, update_row

__all__ = [
    "REDUCIBLE_METHODS",
    "POINTS_METHODS",
    "NNCHAIN_AUTO_MIN_N",
    "NNCHAIN_BATCH_AUTO_MIN_N",
    "ChainResult",
    "nn_chain",
    "nn_chain_from_points",
    "nn_chain_from_summaries",
    "nn_chain_batched",
    "nn_chain_batched_from_points",
    "resolve_algorithm",
    "resolve_batch_algorithm",
    "resolve_matrix_free",
    "summary_distance",
    "summary_merge",
]

#: Linkage methods satisfying the reducibility inequality — the ones the
#: NN-chain algorithm is exact for.  ``centroid``/``median`` can *invert*
#: (a merge may create a nearer pair below the chain), which breaks the
#: chain invariant, so they stay on the LW loop (DESIGN.md §11).
REDUCIBLE_METHODS: tuple[str, ...] = (
    "single", "complete", "average", "weighted", "ward",
)

#: Methods the matrix-free points mode supports: their LW distance is an
#: exact function of the O(d) geometric summary on squared-Euclidean
#: input.  ``ward``'s default metric is already sqeuclidean; ``average``
#: and ``weighted`` need an explicit ``metric="sqeuclidean"``.
POINTS_METHODS: tuple[str, ...] = ("ward", "average", "weighted")

#: Smallest n for which ``algorithm="auto"`` prefers the NN-chain engine
#: over the dense LW loop (measured crossover is far lower — see
#: EXPERIMENTS.md §Perf-5 — but below this size both engines run in
#: single-digit milliseconds and auto stays on the LW path every
#: existing caller was tuned against).
NNCHAIN_AUTO_MIN_N = 256

#: Smallest *bucket* n for which batched/service ``algorithm="auto"``
#: prefers the vmapped **matrix-free** NN-chain engine over the batched
#: LW loop.  The trade differs from the serial crossover: under vmap the
#: chain loop's per-lane dynamic reads lower to gathers (~tens of ns per
#: element on XLA:CPU vs ~1 ns for the LW loop's big fused selects) and
#: ``lax.cond`` executes both branches, so the *dense* batched chain
#: only ties the compacted LW bucket (0.8–1.3x measured) and auto keeps
#: dense buckets on LW at every size.  The points composition has no
#: per-lane matrix gathers — its row build is one elementwise
#: ``(B, n, d)`` pass — and beats the compacted LW bucket ≥1.5x from
#: this bucket size up (4–11x by bucket 128–256; measured in
#: benchmarks/bench_service.py, EXPERIMENTS.md §Service).
NNCHAIN_BATCH_AUTO_MIN_N = 64

#: Smallest n for which ``matrix_free="auto"`` drops the dense matrix on
#: capable inputs: below this the (n, n) build is a few MB and the dense
#: row scan is faster than the summary arithmetic.
MATRIX_FREE_AUTO_MIN_N = 4096

_F32 = jnp.float32
_INF = jnp.float32(jnp.inf)


class ChainResult(NamedTuple):
    """:class:`~repro.core.engine.LWResult` plus the measured loop-trip
    count.

    Duck-types ``LWResult`` (``merges``/``n_merges`` first, the
    ``DistributedChainResult`` convention) and adds ``iters`` — how many
    chain-loop trips the run actually executed.  Each trip performs
    exactly ONE candidate-row build (O(n) distances dense, O(n·d) work
    points mode), so ``iters × row_length`` is the *measured* number of
    distance evaluations inside the compiled loop — the number the
    landmark tier's :class:`~repro.core.distance.DistanceBudget`
    records, since host-side hooks cannot see inside a ``while_loop``
    (DESIGN.md §15).  A clean run satisfies ``iters ≤ 2(n−1)`` pushes +
    merges; the static cap is ``4n + 8``.
    """

    merges: jax.Array
    n_merges: jax.Array
    iters: jax.Array


# ---------------------------------------------------------------------------
# knob resolution (the `cluster` API defers here)
# ---------------------------------------------------------------------------


def resolve_algorithm(
    flag: str,
    *,
    method: str,
    backend: str,
    n: int,
    variant: str = "baseline",
    compaction=None,
) -> str:
    """Canonical ``algorithm=`` switch for a ``cluster`` call.

    ``"lw"`` / ``"nnchain"`` are explicit (``"nnchain"`` validates the
    method is reducible and the backend is one the chain loop has a
    composition for: the serial single-device loop, or the sharded
    matrix-free points engine on ``backend="distributed"``
    (:func:`repro.core.distributed.distributed_nn_chain_from_points`);
    the kernel backend keeps the LW engine).  ``"auto"`` picks nnchain
    only for the *default-knob* serial path — reducible method, ``n ≥``
    :data:`NNCHAIN_AUTO_MIN_N`, baseline variant, untouched compaction —
    so callers that pin LW engine knobs (``variant=``, an explicit
    ``compaction=``) keep the engine those knobs belong to, and a
    multi-device ``auto`` backend keeps the LW row-sharded loop (the
    distributed chain is explicit opt-in).
    """
    if flag == "lw":
        return "lw"
    if flag == "nnchain":
        if method not in REDUCIBLE_METHODS:
            raise ValueError(
                f"algorithm='nnchain' needs a reducible method "
                f"{REDUCIBLE_METHODS}, got {method!r} (centroid/median can "
                "produce inversions that break the chain invariant; use "
                "algorithm='lw')"
            )
        if backend not in ("auto", "serial", "distributed"):
            raise ValueError(
                f"algorithm='nnchain' has serial and distributed "
                f"compositions; backend={backend!r} keeps the LW merge "
                "loop (pass backend='serial'/'distributed' or "
                "algorithm='lw')"
            )
        return "nnchain"
    if flag != "auto":
        raise ValueError(
            f"algorithm must be 'auto', 'lw' or 'nnchain', got {flag!r}"
        )
    if (
        method in REDUCIBLE_METHODS
        and backend == "serial"
        and n >= NNCHAIN_AUTO_MIN_N
        and variant == "baseline"
        and compaction in (None, "auto")
    ):
        return "nnchain"
    return "lw"


def resolve_batch_algorithm(
    flag: str,
    *,
    method: str,
    engine: str,
    bucket_n: int,
    variant: str = "baseline",
    compaction="auto",
    points_capable: bool = False,
) -> str:
    """Canonical ``algorithm=`` switch for one batched/service bucket.

    Mirrors :func:`resolve_algorithm` with the batched trade-offs:
    ``"nnchain"`` is explicit (reducible method, ``serial`` vmap engine —
    the distributed/kernel batch engines keep the LW loop; the dense
    composition is exact but only ties the compacted LW bucket on CPU),
    and ``"auto"`` routes a bucket to the vmapped chain only where it
    *measures* faster: a **matrix-free** bucket (``points_capable`` —
    ``(n, d)`` points input under a :data:`POINTS_METHODS`
    squared-Euclidean convention) of :data:`NNCHAIN_BATCH_AUTO_MIN_N` or
    larger, on the default-knob serial path (baseline variant, untouched
    compaction).  Dense buckets stay on LW under ``auto``: the chain
    loop's per-lane gathers eat its O(n) asymptotic edge at every bucket
    size the grid serves (constant documented at
    :data:`NNCHAIN_BATCH_AUTO_MIN_N`).  Resolved per *bucket*, not per
    batch: one ragged ``cluster_batch`` may legitimately run small
    buckets on LW and large points buckets on nnchain.
    """
    if flag == "lw":
        return "lw"
    if flag == "nnchain":
        if method not in REDUCIBLE_METHODS:
            raise ValueError(
                f"algorithm='nnchain' needs a reducible method "
                f"{REDUCIBLE_METHODS}, got {method!r} (centroid/median can "
                "produce inversions that break the chain invariant; use "
                "algorithm='lw')"
            )
        if engine not in ("auto", "serial"):
            raise ValueError(
                f"batched algorithm='nnchain' is the vmapped single-device "
                f"chain; engine={engine!r} keeps the LW merge loop (pass "
                "engine='serial' or algorithm='lw')"
            )
        return "nnchain"
    if flag != "auto":
        raise ValueError(
            f"algorithm must be 'auto', 'lw' or 'nnchain', got {flag!r}"
        )
    if (
        points_capable
        and method in POINTS_METHODS
        and engine == "serial"
        and bucket_n >= NNCHAIN_BATCH_AUTO_MIN_N
        and variant == "baseline"
        and compaction in (None, False, "auto")
    ):
        return "nnchain"
    return "lw"


def resolve_matrix_free(
    flag,
    *,
    points_shape: tuple | None,
    method: str,
    metric: str | None,
    n: int,
) -> bool:
    """Canonical ``matrix_free=`` switch for the nnchain path.

    ``True`` demands the matrix-free points mode (raises when the input
    or method cannot support it); ``False`` pins the dense matrix;
    ``"auto"`` goes matrix-free exactly when it is *exact and worth it* —
    ``(n, d)`` points input, a :data:`POINTS_METHODS` method under its
    squared-Euclidean convention, and ``n ≥``
    :data:`MATRIX_FREE_AUTO_MIN_N` (where the dense matrix starts to
    cost real memory).
    """
    capable = (
        points_shape is not None
        and len(points_shape) == 2
        and method in POINTS_METHODS
        and metric == "sqeuclidean"
    )
    if flag in (False, None):
        return False
    if flag is True:
        if not capable:
            raise ValueError(
                "matrix_free=True needs (n, d) points input and a method "
                f"whose LW distance is a geometric-summary function "
                f"({POINTS_METHODS}, squared-Euclidean metric); got "
                f"method={method!r}, metric={metric!r}, "
                f"input shape {points_shape}"
            )
        return True
    if flag != "auto":
        raise ValueError(
            f"matrix_free must be a bool or 'auto', got {flag!r}"
        )
    return capable and n >= MATRIX_FREE_AUTO_MIN_N


# ---------------------------------------------------------------------------
# the ONE chain loop
# ---------------------------------------------------------------------------


class NNState(NamedTuple):
    """Carry of the chain loop — shared by both compositions.

    ``rep`` is the cluster representation: ``(D,)`` for the dense
    composition, ``(W, u)`` geometric summaries for points mode.
    ``chain``/``chain_len`` is the NN chain as a fixed-size stack
    (entries past ``chain_len`` are stale garbage).  ``iters`` counts
    loop trips — a static ``4n`` cap bounds the loop against float
    pathologies (NaN rows would otherwise cycle forever); a clean run
    never reaches it (pushes are bounded by ``2(n−1)``).
    """

    rep: tuple
    alive: jax.Array
    sizes: jax.Array
    chain: jax.Array
    chain_len: jax.Array
    merges: jax.Array
    n_merges: jax.Array
    iters: jax.Array


class NNChainOps(NamedTuple):
    """The two primitives a chain-loop composition supplies.

    row:   ``(state, top) -> (n,)`` current *raw* distances from cluster
           ``top`` to every slot — ONE O(n) (dense) / O(n·d) (points)
           pass.  The chain loop owns the liveness mask (dead slots and
           ``top`` itself go ``+inf`` before the min), so the raw row
           can be handed to ``merge`` unmasked.
    merge: ``(state, i, j, dmin, top, row_top) -> state`` — commit the
           merge into the representation (O(n) dense row rewrite, O(d)
           summary update), leaving ``alive``/``sizes`` untouched (the
           shared skeleton owns that bookkeeping).  ``row_top`` is the
           raw ``row(state, top)`` already computed this trip — the
           dense composition reuses it as the ``top`` side of the LW
           recurrence instead of paying a second per-lane row read
           (under vmap those reads are per-lane gathers, the dominant
           batched cost).
    """

    row: Callable[[NNState, jax.Array], jax.Array]
    merge: Callable[..., NNState]


def _scalar_set(vec: jax.Array, idx: jax.Array, value) -> jax.Array:
    """O(1) element write as a dynamic-update-slice (never a scatter —
    the XLA:CPU scatter path costs ~µs per element)."""
    upd = jnp.asarray(value, vec.dtype)[None]
    return jax.lax.dynamic_update_slice(vec, upd, (idx,))


def _chain_loop(
    ops: NNChainOps, state: NNState, n_steps: int | jax.Array
) -> NNState:
    """Run the NN-chain loop until ``n_steps`` merges are recorded.

    Each trip either *extends* the chain by the tip's nearest neighbor
    or *merges* the top two elements when they are mutual nearest
    neighbors.  Mutuality is detected by preferring the previous chain
    element on distance ties (``row[prev] == m`` picks ``prev``): the
    chain's distances are non-increasing, so an equality at the tip IS
    reciprocity — and the preference also rules out tie cycles revisiting
    older chain entries.  All index bookkeeping is dynamic-update-slice,
    never a scatter, and the argmin is the engine's vectorized
    min + first-index recovery (XLA:CPU scalarizes variadic reduces).

    ``n_steps`` may be a *traced* scalar (the batched compositions pass
    each lane's ``max(n_real − 1, 0)``): the merge buffer's static row
    count comes from :func:`_init_state`, and under ``vmap`` the
    while_loop batching rule turns the per-lane cond into
    ``any(cond)`` + per-lane ``select`` — lanes whose target is met stop
    absorbing body results while slower lanes run on (the frozen-lane
    invariant, same mechanism as the LW ``distance_threshold`` loop).
    """
    if isinstance(n_steps, int) and n_steps <= 0:
        return state
    n = state.alive.shape[0]
    ks = jnp.arange(n)
    iter_cap = jnp.int32(4 * n + 8)

    def cond(s: NNState):
        return (s.n_merges < n_steps) & (s.iters < iter_cap)

    def body(s: NNState) -> NNState:
        empty = s.chain_len == 0
        first_live = _first_where(s.alive, ks, n).astype(jnp.int32)
        chain = _scalar_set(
            s.chain, jnp.int32(0), jnp.where(empty, first_live, s.chain[0])
        )
        length = jnp.where(empty, jnp.int32(1), s.chain_len)
        top = jax.lax.dynamic_index_in_dim(chain, length - 1, keepdims=False)
        prev = jnp.where(
            length >= 2,
            jax.lax.dynamic_index_in_dim(
                chain, jnp.maximum(length - 2, 0), keepdims=False
            ),
            jnp.int32(n),
        )
        row_raw = ops.row(s, top)
        row = jnp.where(s.alive & (ks != top), row_raw, _INF)
        m = jnp.min(row)
        prev_hit = (prev < n) & (row[jnp.minimum(prev, n - 1)] == m)
        c = jnp.where(
            prev_hit, prev, _first_where(row == m, ks, n).astype(jnp.int32)
        )

        def do_merge(s: NNState) -> NNState:
            i, j = jnp.minimum(top, c), jnp.maximum(top, c)
            new_size = s.sizes[i] + s.sizes[j]
            s = ops.merge(s, i, j, m, top, row_raw)
            record = jnp.stack(
                [i.astype(_F32), j.astype(_F32), m, new_size]
            )[None, :]
            return s._replace(
                alive=_scalar_set(s.alive, j, False),
                sizes=_scalar_set(
                    _scalar_set(s.sizes, i, new_size), j, 0.0
                ),
                merges=jax.lax.dynamic_update_slice(
                    s.merges, record, (s.n_merges, jnp.int32(0))
                ),
                n_merges=s.n_merges + 1,
                chain=chain,
                chain_len=length - 2,
            )

        def do_push(s: NNState) -> NNState:
            return s._replace(
                chain=_scalar_set(chain, length, c),
                chain_len=length + 1,
            )

        s = jax.lax.cond(prev_hit, do_merge, do_push, s)
        return s._replace(iters=s.iters + 1)

    return jax.lax.while_loop(cond, body, state)


def _init_state(
    rep: tuple, alive: jax.Array, n_steps: int, sizes: jax.Array | None = None
) -> NNState:
    """Fresh chain-loop carry.  ``sizes`` defaults to unit weight per live
    slot (leaves); the summaries entry point passes pre-accumulated
    cluster sizes (two-phase tier, slots are whole clusters)."""
    n = alive.shape[0]
    return NNState(
        rep=rep,
        alive=alive,
        sizes=alive.astype(_F32) if sizes is None else sizes,
        chain=jnp.zeros((n,), jnp.int32),
        chain_len=jnp.zeros((), jnp.int32),
        merges=jnp.zeros((max(n_steps, 0), 4), _F32),
        n_merges=jnp.zeros((), jnp.int32),
        iters=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# dense composition
# ---------------------------------------------------------------------------


def _dense_nnchain_ops(method: str, n: int) -> NNChainOps:
    """Garbage-representation dense primitives: mask at read, and — the
    load-bearing trick — **row-only writes with a version vector**.

    A merge must update slot ``i``'s distances for every future reader.
    The obvious commit (row *and* column ``i``) is O(n) cells, but a
    *column* ``dynamic_update_slice`` on the loop-carried matrix defeats
    XLA:CPU's in-place buffer reuse and silently copies all O(n²) cells
    per merge — measured, it turns the whole engine cubic (EXPERIMENTS.md
    §Perf-5).  So the merge writes ONLY row ``i`` (a genuine in-place
    DUS) and bumps ``version[i]`` to the merge index; any later read of
    slot ``t``'s distances reconstructs the current row from whichever
    side was written more recently::

        d(t, k) = D[k, t]  if version[k] > version[t]   (column read)
                  D[t, k]  otherwise                    (row read)

    — correct because a slot's cluster only changes when its row is
    rewritten, so the later write of the pair saw the other side's
    current state.  Both reads are O(n) slices; dead slots hold inert
    garbage masked at read.
    """
    ks = jnp.arange(n)

    def current_row(rep: tuple, t: jax.Array) -> jax.Array:
        D, ver = rep
        r_row = jax.lax.dynamic_slice_in_dim(D, t, 1, axis=0)[0]
        r_col = jax.lax.dynamic_slice(D, (jnp.int32(0), t), (n, 1))[:, 0]
        return jnp.where(ver > ver[t], r_col, r_row)

    def row(s: NNState, top: jax.Array) -> jax.Array:
        return current_row(s.rep, top)

    def merge(s: NNState, i, j, dmin, top, row_top) -> NNState:
        D, ver = s.rep
        # {i, j} == {top, c}: top's current row was computed this trip,
        # so only the partner pays a fresh (gathering) row read
        row_c = current_row(s.rep, jnp.where(top == i, j, i))
        d_ki = jnp.where(top == i, row_top, row_c)
        d_kj = jnp.where(top == i, row_c, row_top)
        keep = s.alive & (ks != i) & (ks != j)
        new = update_row(method, d_ki, d_kj, dmin, s.sizes[i], s.sizes[j],
                         s.sizes)
        new = jnp.where(keep, new, 0.0)        # garbage rep: dead cells inert
        D = jax.lax.dynamic_update_slice(D, new[None, :], (i, jnp.int32(0)))
        ver = _scalar_set(ver, i, s.n_merges + 1)
        return s._replace(rep=(D, ver))

    return NNChainOps(row=row, merge=merge)


@partial(jax.jit, static_argnames=("method",))
def _run_dense(D: jax.Array, *, method: str) -> ChainResult:
    D = symmetrize(D)
    n = D.shape[0]
    rep = (D, jnp.zeros((n,), jnp.int32))
    state = _init_state(rep, jnp.ones((n,), bool), n - 1)
    out = _chain_loop(_dense_nnchain_ops(method, n), state, n - 1)
    return ChainResult(merges=out.merges, n_merges=out.n_merges,
                       iters=out.iters)


def nn_chain(D: jax.Array, method: str = "complete") -> ChainResult:
    """Full agglomeration of an ``(n, n)`` distance matrix via NN-chain.

    O(n²) total work, exact for the reducible methods.  Merges are in
    **chain order** — pass them through
    :func:`repro.core.dendrogram.canonical_order` before cutting (the
    ``cluster`` API does this for you); the canonicalized list matches
    the LW engine's output on tie-free input.
    """
    if method not in METHODS:
        raise ValueError(f"unknown linkage method {method!r}")
    if method not in REDUCIBLE_METHODS:
        raise ValueError(
            f"nn_chain is exact only for reducible methods "
            f"{REDUCIBLE_METHODS}, got {method!r}"
        )
    D = jnp.asarray(D, _F32)
    if D.ndim != 2 or D.shape[0] != D.shape[1]:
        raise ValueError(f"distance matrix must be square, got {D.shape}")
    if D.shape[0] < 2:
        return ChainResult(merges=jnp.zeros((0, 4), _F32),
                           n_merges=jnp.zeros((), jnp.int32),
                           iters=jnp.zeros((), jnp.int32))
    return _run_dense(D, method=method)


# ---------------------------------------------------------------------------
# matrix-free points composition
# ---------------------------------------------------------------------------


def summary_distance(method, sq, u_k, u_top, n_k, n_top):
    """LW distance from geometric summaries, given ``sq = ‖w_top − w_k‖²``.

    Broadcasts: ``sq``/``u_k``/``n_k`` may be any shape (a full candidate
    row, or one shard's local slice of it — the distributed composition
    passes the slice), ``u_top``/``n_top`` are the tip's scalars.  Shared
    by the serial, batched and sharded chain engines so their distances
    stay bit-identical (the cross-engine equivalence tests rely on it).
    """
    if method == "ward":
        return 2.0 * n_top * n_k / (n_top + n_k) * sq
    return sq + u_k + u_top                     # average / weighted


def summary_merge(method, w_i, w_j, u_i, u_j, n_i, n_j):
    """Merge two geometric summaries — the O(d) recursion per method.

    Returns ``(w_new, u_new)`` for the union cluster.  ``ward`` keeps the
    size-weighted centroid (Wishart form, ``u ≡ 0``); ``average`` adds
    the exact mean within-cluster scatter combination; ``weighted`` is
    the WPGMA midpoint recursion.  One definition serves the serial,
    batched, sharded and two-phase compositions.
    """
    tot = n_i + n_j
    gap = jnp.sum((w_i - w_j) ** 2)
    if method == "weighted":                # WPGMA midpoint recursion
        w_new = 0.5 * (w_i + w_j)
        u_new = 0.5 * (u_i + u_j) + 0.25 * gap
    elif method == "average":               # size-weighted centroid + scatter
        w_new = (n_i * w_i + n_j * w_j) / tot
        u_new = (n_i * u_i + n_j * u_j) / tot + (n_i * n_j) / (tot * tot) * gap
    else:                                   # ward: centroid only, u ≡ 0
        w_new = (n_i * w_i + n_j * w_j) / tot
        u_new = jnp.zeros((), _F32)
    return w_new, u_new


def _points_nnchain_ops(
    method: str, n: int, *, use_pallas: bool, block_n: int, interpret: bool
) -> NNChainOps:
    """Geometric-summary primitives — O(n·d) row build, O(d) merge.

    The squared-norm row ``‖w_top − w_k‖²`` is the only O(n·d) term; it
    runs through the shared row-build dispatch
    (:func:`repro.kernels.pairwise.row_sq_euclidean`) — one jnp pass by
    default, or tile-by-tile through the Pallas row-vs-points kernel
    when ``use_pallas`` (TPU; validated in interpret mode on CPU).
    Everything else is O(n) epilogue.
    """
    del n  # summaries broadcast; kept for signature stability

    def row(s: NNState, top: jax.Array) -> jax.Array:
        from repro.kernels.pairwise import row_sq_euclidean

        W, u = s.rep
        w_top = jax.lax.dynamic_slice_in_dim(W, top, 1, axis=0)[0]
        sq = row_sq_euclidean(w_top, W, use_pallas=use_pallas,
                              block_n=block_n, interpret=interpret)
        return summary_distance(method, sq, u, u[top], s.sizes, s.sizes[top])

    def merge(s: NNState, i, j, dmin, top, row_top) -> NNState:
        W, u = s.rep
        w_i = jax.lax.dynamic_slice_in_dim(W, i, 1, axis=0)[0]
        w_j = jax.lax.dynamic_slice_in_dim(W, j, 1, axis=0)[0]
        w_new, u_new = summary_merge(
            method, w_i, w_j, u[i], u[j], s.sizes[i], s.sizes[j]
        )
        W = jax.lax.dynamic_update_slice(W, w_new[None, :], (i, jnp.int32(0)))
        return s._replace(rep=(W, _scalar_set(u, i, u_new)))

    return NNChainOps(row=row, merge=merge)


@partial(jax.jit, static_argnames=("method", "n_steps", "use_pallas",
                                   "block_n", "interpret"))
def _run_points(
    X: jax.Array,
    alive: jax.Array,
    *,
    method: str,
    n_steps: int,
    use_pallas: bool,
    block_n: int,
    interpret: bool,
) -> ChainResult:
    n = X.shape[0]
    rep = (jnp.asarray(X, _F32), jnp.zeros((n,), _F32))
    state = _init_state(rep, alive, n_steps)
    ops = _points_nnchain_ops(
        method, n, use_pallas=use_pallas, block_n=block_n, interpret=interpret
    )
    out = _chain_loop(ops, state, n_steps)
    return ChainResult(merges=out.merges, n_merges=out.n_merges,
                       iters=out.iters)


def nn_chain_from_points(
    X: jax.Array,
    method: str = "ward",
    *,
    use_pallas: bool = False,
    block_n: int = 512,
    interpret: bool | None = None,
) -> ChainResult:
    """Matrix-free full agglomeration of ``(n, d)`` points — O(n·d + n)
    peak memory, the ``(n, n)`` matrix is **never** allocated.

    Exact (to float tolerance) against the dense engines run on
    ``pairwise_sq_euclidean(X)`` for :data:`POINTS_METHODS` — the
    squared-Euclidean convention is ``ward``'s default and must be
    requested explicitly (``metric="sqeuclidean"``) for
    ``average``/``weighted`` at the ``cluster`` level.  Merges are in
    chain order, same contract as :func:`nn_chain`.

    ``use_pallas`` routes the per-tip squared-norm row through the tiled
    Pallas row-vs-points kernel (pads ``n`` to a ``block_n`` multiple
    and ``d`` to a lane multiple once, up front; padded slots are born
    dead).  The absence of any (n, n) intermediate is asserted over the
    compiled HLO in ``benchmarks/bench_nnchain.py``.
    """
    if method not in POINTS_METHODS:
        raise ValueError(
            f"matrix-free points mode supports {POINTS_METHODS} (their LW "
            f"distance is a geometric-summary function), got {method!r} — "
            "build the distance matrix and use nn_chain instead"
        )
    X = jnp.asarray(X, _F32)
    if X.ndim != 2:
        raise ValueError(f"expected (n, d) points, got {X.shape}")
    n = int(X.shape[0])
    if n < 2:
        return ChainResult(merges=jnp.zeros((0, 4), _F32),
                           n_merges=jnp.zeros((), jnp.int32),
                           iters=jnp.zeros((), jnp.int32))
    if use_pallas:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        # block stays a 128-lane multiple — Mosaic rejects off-tile blocks
        bn = max(128, min(block_n, n) // 128 * 128)
        n_pad = n + (-n) % bn
        d_pad = X.shape[1] + (-X.shape[1]) % 128
        X = jnp.pad(X, ((0, n_pad - n), (0, d_pad - X.shape[1])))
        alive = jnp.arange(n_pad) < n
        return _run_points(X, alive, method=method, n_steps=n - 1,
                           use_pallas=True, block_n=bn, interpret=interpret)
    return _run_points(X, jnp.ones((n,), bool), method=method, n_steps=n - 1,
                       use_pallas=False, block_n=block_n, interpret=False)


@partial(jax.jit, static_argnames=("method", "n_steps"))
def _run_summaries(
    W: jax.Array,
    u: jax.Array,
    sizes: jax.Array,
    *,
    method: str,
    n_steps: int,
) -> ChainResult:
    n = W.shape[0]
    state = _init_state(
        (W, u), jnp.ones((n,), bool), n_steps, sizes=sizes
    )
    ops = _points_nnchain_ops(
        method, n, use_pallas=False, block_n=512, interpret=False
    )
    out = _chain_loop(ops, state, n_steps)
    return ChainResult(merges=out.merges, n_merges=out.n_merges,
                       iters=out.iters)


def nn_chain_from_summaries(
    W: jax.Array,
    u: jax.Array,
    sizes: jax.Array,
    method: str = "ward",
) -> ChainResult:
    """Agglomerate ``k`` pre-accumulated geometric summaries.

    Each slot is a whole *cluster* — ``W[k]`` its summary point
    (centroid / WPGMA midpoint), ``u[k]`` its scatter term, ``sizes[k]``
    its member count — and the chain runs the same
    :func:`summary_distance`/:func:`summary_merge` recursions as
    :func:`nn_chain_from_points` (which is exactly this call with unit
    sizes and ``u = 0``).  This is phase 2 of the two-phase distributed
    tier (:func:`repro.core.distributed.two_phase_from_points`): shards
    cluster locally, then their surviving summaries agglomerate globally
    here.  Merges are in chain order over summary slots; recorded sizes
    are summed member counts.
    """
    if method not in POINTS_METHODS:
        raise ValueError(
            f"summary agglomeration supports {POINTS_METHODS} (their LW "
            f"distance is a geometric-summary function), got {method!r}"
        )
    W = jnp.asarray(W, _F32)
    if W.ndim != 2:
        raise ValueError(f"expected (k, d) summary points, got {W.shape}")
    k = int(W.shape[0])
    u = jnp.asarray(u, _F32)
    sizes = jnp.asarray(sizes, _F32)
    if u.shape != (k,) or sizes.shape != (k,):
        raise ValueError(
            f"u and sizes must be ({k},) to match the summaries, got "
            f"{u.shape} and {sizes.shape}"
        )
    if k < 2:
        return ChainResult(merges=jnp.zeros((0, 4), _F32),
                           n_merges=jnp.zeros((), jnp.int32),
                           iters=jnp.zeros((), jnp.int32))
    return _run_summaries(W, u, sizes, method=method, n_steps=k - 1)


# ---------------------------------------------------------------------------
# batched compositions (vmap over a shape bucket)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("method", "n_steps"))
def _run_batch(
    Db: jax.Array,
    n_real: jax.Array,
    threshold: jax.Array,
    *,
    method: str,
    n_steps: int,
) -> LWResult:
    """Vmapped dense NN-chain over a ``(B, n, n)`` bucket.

    Same ``(Db, n_real, threshold)`` operand convention as
    :func:`repro.core.batched._run_vmap` so the service AOT cache lowers
    both through one code path.  ``threshold`` is accepted and ignored:
    the chain emits merges in chain order, so early stop is post-hoc
    canonical truncation (module docstring) — the operand only keeps the
    compiled signature uniform.  ``n_steps`` is the *static* merge-buffer
    capacity (``bucket_n − 1``); each lane's actual target is the traced
    ``max(n_real − 1, 0)``, and dead padded lanes (target 0) never
    absorb a body result.
    """
    del threshold  # post-hoc early stop; operand kept for AOT uniformity
    Db = symmetrize(Db)
    n = Db.shape[-1]

    def run(D: jax.Array, n_r: jax.Array) -> LWResult:
        alive = jnp.arange(n) < n_r
        rep = (jnp.where(alive[:, None] & alive[None, :], D, 0.0),
               jnp.zeros((n,), jnp.int32))
        state = _init_state(rep, alive, n_steps)
        target = jnp.minimum(jnp.maximum(n_r - 1, 0), n_steps).astype(jnp.int32)
        out = _chain_loop(_dense_nnchain_ops(method, n), state, target)
        return LWResult(merges=out.merges, n_merges=out.n_merges)

    return jax.vmap(run)(Db, jnp.asarray(n_real, jnp.int32))


def nn_chain_batched(
    Db: jax.Array, n_real, method: str = "complete"
) -> LWResult:
    """Batched NN-chain over a ``(B, n, n)`` shape bucket.

    Lane ``b`` agglomerates ``Db[b, :n_real[b], :n_real[b]]``; rows and
    columns past ``n_real[b]`` are padding (born dead, masked at read).
    Returns stacked chain-order merge buffers — lane ``b``'s real rows
    are ``merges[b, :n_real[b] - 1]``; pass them through
    :func:`repro.core.dendrogram.canonical_order` before cutting, same
    contract as :func:`nn_chain` (``cluster_batch`` does this for you).
    """
    if method not in METHODS:
        raise ValueError(f"unknown linkage method {method!r}")
    if method not in REDUCIBLE_METHODS:
        raise ValueError(
            f"nn_chain is exact only for reducible methods "
            f"{REDUCIBLE_METHODS}, got {method!r}"
        )
    Db = jnp.asarray(Db, _F32)
    if Db.ndim != 3 or Db.shape[1] != Db.shape[2]:
        raise ValueError(
            f"expected a (B, n, n) bucket of distance matrices, got {Db.shape}"
        )
    n_real = jnp.asarray(n_real, jnp.int32)
    if n_real.shape != (Db.shape[0],):
        raise ValueError(
            f"n_real must be ({Db.shape[0]},) to match the bucket, "
            f"got {n_real.shape}"
        )
    n = int(Db.shape[1])
    if n < 2:
        return LWResult(
            merges=jnp.zeros((Db.shape[0], 0, 4), _F32),
            n_merges=jnp.zeros((Db.shape[0],), jnp.int32),
        )
    return _run_batch(Db, n_real, jnp.float32(jnp.inf),
                      method=method, n_steps=n - 1)


@partial(jax.jit, static_argnames=("method", "n_steps"))
def _run_points_batch(
    Xb: jax.Array,
    n_real: jax.Array,
    threshold: jax.Array,
    *,
    method: str,
    n_steps: int,
) -> LWResult:
    """Vmapped matrix-free NN-chain over a ``(B, n, d)`` points bucket —
    pad waste is O(n·d) per lane instead of the dense bucket's O(n²),
    and the per-trip row build has no per-lane matrix gathers at all
    (only ``(B, d)`` summary reads) — the measured service win
    (EXPERIMENTS.md §Service).  ``threshold`` is accepted and ignored,
    same post-hoc contract as :func:`_run_batch`."""
    del threshold  # post-hoc early stop; operand kept for AOT uniformity
    n = Xb.shape[1]

    def run(X: jax.Array, n_r: jax.Array) -> LWResult:
        alive = jnp.arange(n) < n_r
        rep = (jnp.asarray(X, _F32), jnp.zeros((n,), _F32))
        state = _init_state(rep, alive, n_steps)
        target = jnp.minimum(jnp.maximum(n_r - 1, 0), n_steps).astype(jnp.int32)
        ops = _points_nnchain_ops(
            method, n, use_pallas=False, block_n=512, interpret=False
        )
        out = _chain_loop(ops, state, target)
        return LWResult(merges=out.merges, n_merges=out.n_merges)

    return jax.vmap(run)(Xb, jnp.asarray(n_real, jnp.int32))


def nn_chain_batched_from_points(
    Xb: jax.Array, n_real, method: str = "ward"
) -> LWResult:
    """Batched matrix-free agglomeration of a ``(B, n, d)`` points bucket.

    Lane ``b`` clusters ``Xb[b, :n_real[b]]`` under the squared-Euclidean
    convention of :func:`nn_chain_from_points` (:data:`POINTS_METHODS`
    only); padding rows are inert.  The ``(n, n)`` matrix is never
    materialized in any lane, so a ragged bucket wastes O(n·d) per
    padded lane, not O(n²).  Merges are in chain order, same contract as
    :func:`nn_chain_batched`.
    """
    if method not in POINTS_METHODS:
        raise ValueError(
            f"matrix-free points mode supports {POINTS_METHODS} (their LW "
            f"distance is a geometric-summary function), got {method!r} — "
            "build the distance matrices and use nn_chain_batched instead"
        )
    Xb = jnp.asarray(Xb, _F32)
    if Xb.ndim != 3:
        raise ValueError(f"expected a (B, n, d) points bucket, got {Xb.shape}")
    n_real = jnp.asarray(n_real, jnp.int32)
    if n_real.shape != (Xb.shape[0],):
        raise ValueError(
            f"n_real must be ({Xb.shape[0]},) to match the bucket, "
            f"got {n_real.shape}"
        )
    n = int(Xb.shape[1])
    if n < 2:
        return LWResult(
            merges=jnp.zeros((Xb.shape[0], 0, 4), _F32),
            n_merges=jnp.zeros((Xb.shape[0],), jnp.int32),
        )
    return _run_points_batch(Xb, n_real, jnp.float32(jnp.inf),
                             method=method, n_steps=n - 1)
