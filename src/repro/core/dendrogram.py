"""Dendrogram utilities: merge list → tree / labels / linkage matrix.

The engines emit a ``(n-1, 4)`` *merge list* in slot convention —
``(i, j, dist, new_size)`` with ``i < j``, slot ``i`` keeping the union —
which is exactly the paper's "output the current tree level" step.  This
module is the host-side post-processing: conversion to a scipy-style
linkage matrix, flat cluster extraction at any level ``k`` (the paper's
"look k levels down the tree"), and tree invariant checks used by the
property tests.  Pure numpy; nothing here is performance-critical.
"""

from __future__ import annotations

import numpy as np


def _leaf_count(merges: np.ndarray, n: int | None) -> int:
    """Number of leaves.  ``n`` must be given for early-stopped runs,
    whose merge lists are shorter than ``n - 1``."""
    m = merges.shape[0]
    if n is None:
        return m + 1
    if not m <= n - 1:
        raise ValueError(f"{m} merges is too many for n={n} leaves")
    return n


def to_linkage_matrix(merges: np.ndarray, n: int | None = None) -> np.ndarray:
    """Convert slot-convention merges to a scipy-style linkage matrix ``Z``.

    Row ``t`` of ``Z`` is ``(id_a, id_b, dist, size)`` where ids ``< n`` are
    leaves and id ``n + t`` names the cluster created at step ``t``.  For
    an early-stopped run pass the leaf count ``n`` explicitly; ``Z`` then
    has one row per performed merge (a truncated forest).
    """
    merges = np.asarray(merges)
    n = _leaf_count(merges, n)
    slot_id = np.arange(n)          # which cluster-id currently sits in a slot
    Z = np.zeros((merges.shape[0], 4))
    for t in range(merges.shape[0]):
        i, j, dist, size = merges[t]
        i, j = int(round(i)), int(round(j))
        a, b = slot_id[i], slot_id[j]
        Z[t] = (min(a, b), max(a, b), dist, size)
        slot_id[i] = n + t
    return Z


def cut(merges: np.ndarray, k: int, n: int | None = None) -> np.ndarray:
    """Flat labels for ``k`` clusters — apply the first ``n-k`` merges.

    Labels are contiguous ints in ``[0, k)`` ordered by first appearance.
    For an early-stopped run pass ``n`` explicitly; ``k`` can then reach
    down only to the stop level ``n - len(merges)``.
    """
    merges = np.asarray(merges)
    n = _leaf_count(merges, n)
    if not 1 <= k <= n:
        raise ValueError(f"k must be in [1, {n}], got {k}")
    if n - k > merges.shape[0]:
        raise ValueError(
            f"cannot cut at k={k}: this run stopped early after "
            f"{merges.shape[0]} merges (k >= {n - merges.shape[0]} required)"
        )
    parent = np.arange(n)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for t in range(n - k):
        i, j = int(round(merges[t, 0])), int(round(merges[t, 1]))
        parent[find(j)] = find(i)

    roots = np.array([find(a) for a in range(n)])
    _, labels = np.unique(roots, return_inverse=True)
    # re-index by first appearance for determinism
    order = {}
    out = np.empty(n, np.int64)
    for a, lab in enumerate(labels):
        if lab not in order:
            order[lab] = len(order)
        out[a] = order[lab]
    return out


def cut_exemplars(
    merges: np.ndarray, k: int, D: np.ndarray, n: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Cut at ``k`` clusters and pick one *exemplar* (medoid) per cluster.

    ``D`` is the ``(n, n)`` distance matrix the tree was built from.
    Returns ``(labels, exemplars)`` where ``exemplars[c]`` is the leaf of
    cluster ``c`` minimizing the summed distance to the cluster's other
    members (ties go to the lowest leaf index).  The exemplars are the
    per-cluster representatives the streaming-assignment service exports
    (:mod:`repro.service.assign`): a new point is labeled by one
    pairwise-distance call against ``k`` exemplars instead of a full
    re-cluster.
    """
    D = np.asarray(D)
    labels = cut(merges, k, n=n)
    if D.shape != (labels.size, labels.size):
        raise ValueError(
            f"distance matrix {D.shape} does not match n={labels.size} leaves"
        )
    exemplars = np.empty(k, np.int64)
    for c in range(k):
        members = np.flatnonzero(labels == c)
        sub = D[np.ix_(members, members)]
        exemplars[c] = members[int(np.argmin(sub.sum(axis=1)))]
    return labels, exemplars


def canonical_order(
    merges: np.ndarray,
    n: int | None = None,
    *,
    rtol: float = 1e-5,
    atol: float = 1e-7,
) -> np.ndarray:
    """Rewrite a merge list into canonical (non-decreasing height) order.

    The NN-chain engine (:mod:`repro.core.nnchain`) emits merges in
    *chain* order; a **stable** sort by height produces exactly the
    sequence the LW loop emits for the same (tie-free) input — same
    slot pairs (a cluster's slot is the minimum leaf index of its
    members in both engines), heights to float tolerance — because for
    reducible methods a child merge never has a greater height than its
    parent, so the stable sort keeps every dependent pair in dependency
    order.

    Reducibility is exact in real arithmetic but only *approximate* in
    float32: duplicated/quantized points can give a parent merge a
    height one ulp **below** its child's (observed: parent 0.99999976
    under child 1.0 on 4× duplicated points), and a naive sort would
    then order the parent first and corrupt the tree.  So heights are
    first **dependency-clamped**: scanning in emission order, a merge
    whose height falls below the clusters it consumes by at most the
    ``rtol``/``atol`` float-noise budget is lifted to that height
    (within the engines' documented height tolerance); a drop *beyond*
    the budget is a genuine inversion (non-reducible input) and is left
    for :func:`validate_merges` to reject after the sort.  Already
    height-sorted input (every LW engine's output) passes through
    unchanged.
    """
    merges = np.array(merges, copy=True)         # input dtype preserved
    n = _leaf_count(merges, n)
    heights = merges[:, 2]
    floor = np.zeros(n, heights.dtype)  # height of the slot's current cluster
    for t in range(merges.shape[0]):
        i, j = int(round(merges[t, 0])), int(round(merges[t, 1]))
        need = max(floor[i], floor[j])
        if heights[t] < need and heights[t] >= need - (atol + rtol * abs(need)):
            heights[t] = need    # float noise, not a real inversion
        floor[i] = heights[t]
    order = np.argsort(heights, kind="stable")
    out = merges[order]
    validate_merges(out, n=n)
    return out


def truncate_canonical(
    merges: np.ndarray,
    n: int,
    stop_at_k: int = 1,
    distance_threshold: float | None = None,
) -> np.ndarray:
    """Apply the LW loop's early-stop semantics to a *canonical* (height-
    sorted) full merge list: keep the first ``n − stop_at_k`` rows, then
    drop everything from the first merge above the threshold on.

    This is the post-hoc half of the NN-chain early-stop contract
    (``cluster``'s docstring): the chain engine always runs the full
    O(n²) agglomeration, and every consumer — the single-problem
    ``cluster`` path, the batched scheduler, the service batcher — cuts
    the :func:`canonical_order` output through this one function so the
    prefix matches what the LW loop's genuine early exit records.  The
    row count comes from the same
    :func:`repro.core.engine.resolve_n_steps` the LW loop trips on —
    one source of truth for the prefix contract.
    """
    from repro.core.engine import resolve_n_steps

    merges = np.asarray(merges)[: resolve_n_steps(n, stop_at_k)]
    if distance_threshold is not None:
        above = merges[:, 2] > distance_threshold
        if above.any():
            merges = merges[: int(np.argmax(above))]
    return merges


def merge_leafsets(merges: np.ndarray, n: int | None = None) -> list[frozenset]:
    """Leaf members of the cluster each merge creates, in merge order.

    The clusters of a dendrogram form a laminar family, so each merge's
    leafset is unique — the list doubles as a canonical identity for
    order-insensitive comparison (:func:`merges_equivalent`).
    """
    merges = np.asarray(merges)
    n = _leaf_count(merges, n)
    members: list[set] = [{a} for a in range(n)]
    out: list[frozenset] = []
    for t in range(merges.shape[0]):
        i, j = int(round(merges[t, 0])), int(round(merges[t, 1]))
        members[i] = members[i] | members[j]
        out.append(frozenset(members[i]))
    return out


def merges_equivalent(
    a: np.ndarray,
    b: np.ndarray,
    n: int | None = None,
    *,
    rtol: float = 1e-4,
    atol: float = 1e-5,
) -> bool:
    """True iff two merge lists describe the same dendrogram.

    Order-insensitive: each list is reduced to its set of created
    clusters (leafsets) with attached heights; the lists are equivalent
    when the cluster sets coincide and per-cluster heights agree to
    tolerance.  This is the cross-engine contract the NN-chain goldens
    assert (``tests/test_nnchain.py``, ``benchmarks/bench_nnchain.py``) —
    robust to both merge reordering and float-level height differences.
    """
    a, b = np.asarray(a), np.asarray(b)
    if a.shape != b.shape:
        return False
    ha = dict(zip(merge_leafsets(a, n), a[:, 2]))
    hb = dict(zip(merge_leafsets(b, n), b[:, 2]))
    if set(ha) != set(hb):
        return False
    va = np.array([ha[k] for k in sorted(ha, key=sorted)])
    vb = np.array([hb[k] for k in sorted(hb, key=sorted)])
    return bool(np.allclose(va, vb, rtol=rtol, atol=atol))


def merge_set_agreement(
    a: np.ndarray, b: np.ndarray, n: int | None = None
) -> float:
    """Fraction of created clusters two merge lists share, in ``[0, 1]``.

    Each list is reduced to its set of created leafsets
    (:func:`merge_leafsets`, heights ignored); the score is
    ``|A ∩ B| / max(|A|, |B|)`` — 1.0 iff the trees have identical
    structure.  This is the measured quality gate for the approximate
    tiers (:func:`repro.core.distributed.two_phase_from_points`): the
    two-phase dendrogram's agreement with the exact engine's is
    *reported* in ``benchmarks/bench_distributed.py`` / EXPERIMENTS.md
    rather than assumed.  Compare full runs of the same ``n`` — truncated
    prefixes score against whatever the other list built.
    """
    sa = set(merge_leafsets(a, n))
    sb = set(merge_leafsets(b, n))
    denom = max(len(sa), len(sb))
    return len(sa & sb) / denom if denom else 1.0


def _contingency(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Dense ``(ka, kb)`` contingency table of two label vectors."""
    a = np.asarray(a).ravel()
    b = np.asarray(b).ravel()
    if a.shape != b.shape:
        raise ValueError(
            f"label vectors must have equal length, got {a.shape} vs {b.shape}"
        )
    _, ai = np.unique(a, return_inverse=True)
    _, bi = np.unique(b, return_inverse=True)
    ka, kb = int(ai.max(initial=-1)) + 1, int(bi.max(initial=-1)) + 1
    table = np.zeros((ka, kb), np.int64)
    np.add.at(table, (ai, bi), 1)
    return table


def adjusted_rand_index(a: np.ndarray, b: np.ndarray) -> float:
    """Adjusted Rand index between two flat labelings, in ``[-1, 1]``.

    Pair-counting agreement corrected for chance: 1.0 iff the labelings
    induce the same partition (invariant to label permutation), and
    ≈ 0 in expectation for two *independent* random labelings — which
    is exactly why the approximate-tier quality harness reports it
    alongside :func:`label_agreement` (a high raw agreement on a
    lopsided labeling can be chance; a high ARI cannot).  Pure numpy,
    O(n + ka·kb).
    """
    table = _contingency(a, b)
    n = table.sum()
    if n < 2:
        return 1.0

    def comb2(x):
        return x * (x - 1) / 2.0

    sum_ij = comb2(table.astype(np.float64)).sum()
    sum_a = comb2(table.sum(axis=1).astype(np.float64)).sum()
    sum_b = comb2(table.sum(axis=0).astype(np.float64)).sum()
    total = comb2(float(n))
    expected = sum_a * sum_b / total
    max_index = 0.5 * (sum_a + sum_b)
    if max_index == expected:       # both labelings trivial (all one cluster
        return 1.0                  # or all singletons): identical partitions
    return float((sum_ij - expected) / (max_index - expected))


def label_agreement(a: np.ndarray, b: np.ndarray) -> float:
    """Fraction of items whose labels agree under a greedy cluster match.

    Clusters of ``a`` are matched to clusters of ``b`` greedily by
    descending overlap (each cluster used at most once — deterministic:
    ties break on lowest cluster ids); the score is the matched overlap
    mass over ``n``, in ``[0, 1]``.  Invariant to label permutation and
    1.0 iff the partitions are identical.  This is the "did the
    approximate tier put the points in the same clusters" number the
    landmark gate asserts; report :func:`adjusted_rand_index` next to it
    for the chance-corrected view.
    """
    table = _contingency(a, b)
    n = table.sum()
    if n == 0:
        return 1.0
    flat = [
        (-int(table[i, j]), i, j)
        for i in range(table.shape[0])
        for j in range(table.shape[1])
        if table[i, j] > 0
    ]
    flat.sort()
    used_a: set[int] = set()
    used_b: set[int] = set()
    matched = 0
    for neg, i, j in flat:
        if i in used_a or j in used_b:
            continue
        used_a.add(i)
        used_b.add(j)
        matched += -neg
    return matched / float(n)


def cut_label_agreement(
    merges_a: np.ndarray,
    merges_b: np.ndarray,
    k: int,
    n: int | None = None,
) -> float:
    """:func:`label_agreement` between the ``k``-cuts of two dendrograms.

    Cuts both merge lists at ``k`` clusters over the same ``n`` leaves
    and scores the flat partitions.  This is the *measured* quality gate
    of the approximate tiers (landmark, two-phase): the score against
    the exact engine's dendrogram is reported in
    ``benchmarks/bench_landmark.py`` / EXPERIMENTS.md §Perf-10 and
    asserted ≥ its floor in CI — never assumed.  Complements
    :func:`merge_set_agreement` (tree structure) with a
    partition-at-the-cut view, which is what the serving path (labels,
    exemplars, streaming assignment) actually exposes.
    """
    return label_agreement(cut(merges_a, k, n=n), cut(merges_b, k, n=n))


def merge_heights(merges: np.ndarray) -> np.ndarray:
    return np.asarray(merges)[:, 2]


def is_monotone(merges: np.ndarray, atol: float = 1e-5) -> bool:
    """True iff merge heights are non-decreasing.

    Guaranteed for single/complete/average/weighted/ward (reducible
    linkages); centroid/median may legally produce inversions.
    """
    h = merge_heights(merges)
    return bool(np.all(np.diff(h) >= -atol * np.maximum(1.0, np.abs(h[:-1]))))


def validate_merges(merges: np.ndarray, n: int | None = None) -> None:
    """Structural invariants every engine must satisfy (property tests).

    * each step merges two distinct live slots, ``i < j``
    * slot ``j`` never reappears after being tombstoned
    * sizes sum correctly (the final merge of a *full* run has size ``n``)
    """
    merges = np.asarray(merges)
    n = _leaf_count(merges, n)
    alive = np.ones(n, bool)
    sizes = np.ones(n)
    for t in range(merges.shape[0]):
        i, j = int(round(merges[t, 0])), int(round(merges[t, 1]))
        if not (0 <= i < j < n):
            raise AssertionError(f"step {t}: bad slot pair ({i}, {j})")
        if not (alive[i] and alive[j]):
            raise AssertionError(f"step {t}: merging dead slot ({i}, {j})")
        sizes[i] += sizes[j]
        if abs(sizes[i] - merges[t, 3]) > 1e-3:
            raise AssertionError(
                f"step {t}: recorded size {merges[t, 3]} != {sizes[i]}"
            )
        alive[j] = False
    if n > 1 and merges.shape[0] == n - 1:   # full run: one cluster remains
        if abs(sizes[int(round(merges[-1, 0]))] - n) > 1e-3:
            raise AssertionError("final cluster does not contain all items")
