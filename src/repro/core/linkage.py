"""Lance-Williams linkage coefficient table (paper Table 1).

The Lance-Williams update expresses the distance between a newly merged
cluster ``i ∪ j`` and any other cluster ``k`` as a recurrence over the
pre-merge distances::

    D(k, i∪j) = a_i * D(k,i) + a_j * D(k,j) + b * D(i,j) + g * |D(k,i) - D(k,j)|

with coefficients ``(a_i, a_j, b, g)`` that depend on the linkage *method*
and (for the size-weighted methods) on the cluster cardinalities
``n_i, n_j, n_k``.  This module is the single source of truth for those
coefficients; the serial engine, the distributed engine, the Pallas kernel
and the numpy oracle all consume it.

Notes
-----
* ``centroid``, ``median`` and ``ward`` assume the input matrix holds
  **squared** Euclidean distances (the usual convention, same as scipy).
* Coefficients are returned broadcast against ``n_k`` so that a single
  fused vector op can update an entire row of the distance matrix —
  ``ward`` genuinely depends on ``n_k`` element-wise.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Canonical method names, ordered as in the paper's Table 1 (+ median).
METHODS: tuple[str, ...] = (
    "single",
    "complete",
    "average",
    "weighted",
    "centroid",
    "median",
    "ward",
)

#: Methods whose recurrences are exact in **squared** Euclidean distances.
GEOMETRIC_METHODS: tuple[str, ...] = ("centroid", "median", "ward")


def default_metric(method: str) -> str:
    """The metric convention for *method* when the caller passes points.

    Squared Euclidean for the geometric methods (their recurrences are
    exact in squared distances), plain Euclidean otherwise — scipy's
    convention.  This is the single source of that rule; the ``cluster``
    APIs and ``lance_williams_from_points`` both defer here.
    """
    if method not in METHODS:
        raise ValueError(f"unknown linkage method {method!r}; pick from {METHODS}")
    return "sqeuclidean" if method in GEOMETRIC_METHODS else "euclidean"


def coefficients(method: str, n_i, n_j, n_k):
    """Return ``(a_i, a_j, b, g)`` for *method*, broadcast against ``n_k``.

    Parameters
    ----------
    method: one of :data:`METHODS` (static — dispatched at trace time).
    n_i, n_j: scalar cluster sizes of the two clusters being merged.
    n_k: scalar or ``(n,)`` vector of sizes of the spectator cluster(s).

    All arithmetic is float32 so the formula stays exact under jit on TPU.
    """
    if method not in METHODS:
        raise ValueError(f"unknown linkage method {method!r}; pick from {METHODS}")

    n_i = jnp.asarray(n_i, jnp.float32)
    n_j = jnp.asarray(n_j, jnp.float32)
    n_k = jnp.asarray(n_k, jnp.float32)
    zero = jnp.zeros_like(n_k)
    half = jnp.full_like(n_k, 0.5)

    if method == "single":
        return half, half, zero, zero - 0.5
    if method == "complete":
        return half, half, zero, zero + 0.5
    if method == "average":
        tot = n_i + n_j
        return (n_i / tot) + zero, (n_j / tot) + zero, zero, zero
    if method == "weighted":
        return half, half, zero, zero
    if method == "centroid":
        tot = n_i + n_j
        return (
            (n_i / tot) + zero,
            (n_j / tot) + zero,
            (-(n_i * n_j) / (tot * tot)) + zero,
            zero,
        )
    if method == "median":
        return half, half, zero - 0.25, zero
    # ward — the only method whose coefficients vary with the spectator size.
    tot = n_i + n_j + n_k
    return (n_i + n_k) / tot, (n_j + n_k) / tot, -n_k / tot, zero


def update_row(method: str, d_ki, d_kj, d_ij, n_i, n_j, n_k):
    """Apply the Lance-Williams recurrence to a whole row at once.

    ``d_ki``/``d_kj`` are the distances from every spectator ``k`` to the two
    merging clusters; the return value is ``D(k, i∪j)`` for every ``k``.
    This is the formula the paper's step 6 applies (and the thing the
    ``lw_update`` Pallas kernel fuses).
    """
    a_i, a_j, b, g = coefficients(method, n_i, n_j, n_k)
    return a_i * d_ki + a_j * d_kj + b * d_ij + g * jnp.abs(d_ki - d_kj)
