"""llama3-405b — dense GQA, 128k vocab [arXiv:2407.21783; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    source="arXiv:2407.21783 (unverified tier)",
    n_layers=126, d_model=16384, n_heads=128, n_kv=8, d_ff=53248,
    vocab=128256, head_dim=128, act="silu",
    rope_theta=500_000.0, norm_eps=1e-5,
    strategy="tp",                  # 128 heads | 16
    remat="nested", microbatches=4, # memory stress case
    opt_state_dtype="int8",         # 8-bit m/v for the ≥300b archs
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=8, n_kv=2, d_ff=192, vocab=512,
    head_dim=8, param_dtype="float32", compute_dtype="float32",
    remat="none", microbatches=1, opt_state_dtype="float32", loss_chunk=64,
)

register("llama3-405b", CONFIG, REDUCED)
