"""Assigned input-shape grid + ``input_specs`` (ShapeDtypeStruct stand-ins).

40 cells = 10 archs × 4 shapes.  ``decode_*`` / ``long_*`` lower
``serve_step`` (one new token against a seq_len-sized cache); ``long_500k``
runs only for the sub-quadratic archs (DESIGN.md §5) — the pure
full-attention archs record a documented skip.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

#: archs with a sub-quadratic long-context mechanism (DESIGN.md §5)
LONG_CONTEXT_OK = {
    "rwkv6-3b",       # O(1) recurrent state
    "zamba2-7b",      # SSM states + 13 shared-attn caches
    "gemma3-1b",      # 5/6 layers local (window 512)
    "mixtral-8x7b",   # SWA rolling ring (window 4096)
}


def cell_runnable(arch_id: str, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and arch_id not in LONG_CONTEXT_OK:
        return False, ("skipped: pure full-attention arch has no "
                       "sub-quadratic long-context mechanism (DESIGN.md §5)")
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    from repro.configs.base import list_archs

    return [(a, s) for a in list_archs() for s in SHAPES]


# ---------------------------------------------------------------------------
# input specs (weak-type-correct, shardable, no allocation)
# ---------------------------------------------------------------------------


def _tok(b: int, s: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    For train that's {tokens, labels, (modality extras)}; for prefill the
    prompt batch; for decode {tokens(b,1)} + the cache tree.
    """
    from repro.models import model_api

    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.compute_dtype)
    d = cfg.d_model

    if shape.kind == "train":
        if cfg.family == "encdec":
            t = cfg.max_target_len
            return {
                "audio_feats": jax.ShapeDtypeStruct((b, s, d), dt),
                "tokens": _tok(b, t),
                "labels": _tok(b, t),
            }
        batch: dict = {"tokens": _tok(b, s), "labels": _tok(b, s)}
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, d), dt)
            batch["mrope_positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        return batch

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "audio_feats": jax.ShapeDtypeStruct((b, s, d), dt),
                "tokens": _tok(b, 1),
            }
        batch = {"tokens": _tok(b, s)}
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, d), dt)
            batch["mrope_positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        return batch

    # decode: one new token against a seq_len cache
    batch = {"tokens": _tok(b, 1)}
    if cfg.family == "vlm":
        batch["mrope_positions"] = jax.ShapeDtypeStruct((3, b, 1), jnp.int32)
    batch["cache"] = model_api.cache_specs(cfg, b, s)
    return batch
