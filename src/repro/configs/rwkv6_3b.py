"""rwkv6-3b "Finch" — attention-free, data-dependent decay
[arXiv:2404.05892; hf]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv",
    source="arXiv:2404.05892; hf (verified)",
    n_layers=32, d_model=2560, n_heads=0, n_kv=0, d_ff=8960,
    vocab=65536, act="relu", use_rope=False,
    rwkv_head_dim=64, norm_type="layer", norm_eps=1e-5,
    strategy="tp", remat="full",
    notes="O(1) state → runs long_500k",
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, d_ff=160, vocab=512, rwkv_head_dim=16,
    param_dtype="float32", compute_dtype="float32", remat="none",
    loss_chunk=64,
)

register("rwkv6-3b", CONFIG, REDUCED)
