"""Paper workload config — the clustering experiment grid.

The paper's experiment (its Figure 2): complete-linkage Lance-Williams over
n ≈ 1968 items, swept over processor counts.  These constants drive
``benchmarks/`` and ``launch/cluster_run.py``.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ClusterConfig:
    name: str = "paper-lw"
    n_items: int = 1968          # the paper's average problem size
    dim: int = 64                # synthetic embedding dim for matrix builds
    atoms: int = 24              # protein-conformation mode: atoms per chain
    method: str = "complete"     # the paper's experimental linkage
    metric: str = "euclidean"
    backend: str = "distributed"
    variant: str = "baseline"    # baseline | rowmin (beyond-paper engine)
    seed: int = 0
    # the paper's processor sweep (Fig. 2 x-axis, adapted to powers of two)
    proc_sweep: tuple[int, ...] = (1, 2, 4, 8, 16)


CONFIG = ClusterConfig()
REDUCED = ClusterConfig(n_items=96, dim=8, atoms=8, proc_sweep=(1, 2, 4))
