"""Model configuration schema + registry for the assigned architectures."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    # identity ---------------------------------------------------------------
    name: str
    family: str                    # dense | moe | ssm | rwkv | hybrid | encdec | vlm
    source: str = ""               # provenance tag from the assignment pool
    # trunk ------------------------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: int = 0              # 0 → d_model // n_heads
    act: str = "silu"
    norm_eps: float = 1e-5
    norm_type: str = "rms"         # rms | layer
    tie_embeddings: bool = False
    sandwich_norm: bool = False    # gemma3 pre+post block norms
    # rope --------------------------------------------------------------------
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0     # gemma3 global layers use 1e6
    rotary_pct: float = 1.0            # chatglm applies RoPE to half the head
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w)
    use_rope: bool = True
    # attention extras ---------------------------------------------------------
    window: int = 0                # sliding window (0 = full attention)
    local_global_period: int = 0   # gemma3: every k-th layer is global
    attn_softcap: float = 0.0
    qk_norm: bool = False
    embed_scale: bool = False      # gemma: embeddings scaled by sqrt(d)
    # MoE ----------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0               # 0 → d_ff
    capacity_factor: float = 1.25
    moe_variant: str = "ep"        # ep (expert-parallel a2a) | gather (§Perf-2)
    # SSM / RWKV ----------------------------------------------------------------
    ssm_state: int = 0
    d_inner: int = 0               # 0 → 2 * d_model
    conv_width: int = 4
    ssm_head_dim: int = 64
    rwkv_head_dim: int = 64
    # hybrid (zamba2) ------------------------------------------------------------
    hybrid_period: int = 0         # every k-th layer = the shared attn block
    # enc-dec (whisper) ------------------------------------------------------------
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    max_target_len: int = 448
    # modality frontend (stubbed per assignment) -----------------------------------
    frontend: str = "none"         # none | audio | vision
    n_img_tokens: int = 0
    # numerics / memory --------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"            # none | full | nested
    scan_layers: bool = True
    microbatches: int = 1
    loss_chunk: int = 512          # tokens per chunked-CE block
    opt_state_dtype: str = "float32"   # float32 | bfloat16 | int8
    # sharding -----------------------------------------------------------------------
    strategy: str = "tp"           # tp | fsdp_cp (see DESIGN.md §6)
    layer_gather: bool = True      # §Perf-1: per-layer FSDP gather in-body
    # bookkeeping ----------------------------------------------------------------------
    notes: str = ""

    # ---- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner_(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def moe_dff_(self) -> int:
        return self.moe_dff or self.d_ff

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so TP over 16 (and lanes) always divides."""
        return self.vocab + ((-self.vocab) % 256)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (used for 6·N·D roofline sanity)."""
        d, hd = self.d_model, self.head_dim_
        attn = d * (self.n_heads + 2 * self.n_kv) * hd + self.n_heads * hd * d
        gated = 3 if self.act in ("silu", "gelu") else 2
        mlp = gated * d * self.d_ff
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        if self.family == "moe":
            mlp = self.n_experts * gated * d * self.moe_dff_
            per_layer = attn + mlp
            return self.n_layers * per_layer + emb
        if self.family == "rwkv":
            tmix = 6 * d * d + 6 * d  # r,k,v,g,o,w projections (+ mixes)
            cmix = 2 * d * self.d_ff
            return self.n_layers * (tmix + cmix) + emb
        if self.family == "ssm":
            din = self.d_inner_
            mix = d * din * 2 + din * d + din * self.conv_width
            return self.n_layers * (mix + mlp) + emb
        if self.family == "hybrid":
            din = self.d_inner_
            n_attn = self.n_layers // max(self.hybrid_period, 1)
            n_mamba = self.n_layers - n_attn
            mamba = d * din * 2 + din * d + din * self.conv_width
            return n_mamba * mamba + 1 * (attn + mlp) + emb  # attn block shared
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp)
            dec = self.n_dec_layers * (2 * attn + mlp)
            return enc + dec + emb
        return self.n_layers * (attn + mlp) + emb

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.head_dim_
        attn = d * (self.n_heads + 2 * self.n_kv) * hd + self.n_heads * hd * d
        gated = 3 if self.act in ("silu", "gelu") else 2
        mlp_active = self.top_k * gated * d * self.moe_dff_
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + mlp_active) + emb


_REGISTRY: dict[str, "ArchEntry"] = {}


@dataclass
class ArchEntry:
    config: ModelConfig
    reduced: ModelConfig


def register(arch_id: str, config: ModelConfig, reduced: ModelConfig) -> None:
    _REGISTRY[arch_id] = ArchEntry(config=config, reduced=reduced)


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    e = _REGISTRY[arch_id]
    return e.reduced if reduced else e.config


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    import importlib

    for mod in (
        "deepseek_coder_33b",
        "chatglm3_6b",
        "llama3_405b",
        "gemma3_1b",
        "zamba2_7b",
        "mixtral_8x7b",
        "grok1_314b",
        "rwkv6_3b",
        "qwen2_vl_2b",
        "whisper_small",
        "paper_lw",
    ):
        importlib.import_module(f"repro.configs.{mod}")
