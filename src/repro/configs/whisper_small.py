"""whisper-small — enc-dec ASR backbone; conv frontend stubbed
[arXiv:2212.04356; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    source="arXiv:2212.04356 (unverified tier)",
    n_layers=24, n_enc_layers=12, n_dec_layers=12,
    d_model=768, n_heads=12, n_kv=12, d_ff=3072,
    vocab=51865,                      # padded to 51968 (vocab_padded)
    head_dim=64, act="gelu_nogate", use_rope=False,
    norm_type="layer", norm_eps=1e-5, max_target_len=448,
    frontend="audio",
    strategy="fsdp_cp",               # 12 heads ∤ 16
    remat="full",
)

REDUCED = CONFIG.replace(
    n_layers=4, n_enc_layers=2, n_dec_layers=2,
    d_model=64, n_heads=4, n_kv=4, d_ff=160, vocab=512,
    head_dim=16, max_target_len=32,
    param_dtype="float32", compute_dtype="float32", remat="none",
    loss_chunk=64,
)

register("whisper-small", CONFIG, REDUCED)
