"""repro.configs — assigned architectures × shapes registry."""

from repro.configs.base import ModelConfig, get_config, list_archs
from repro.configs.shapes import SHAPES, all_cells, cell_runnable, input_specs

__all__ = [
    "ModelConfig",
    "SHAPES",
    "all_cells",
    "cell_runnable",
    "get_config",
    "input_specs",
    "list_archs",
]
