"""qwen2-vl-2b — VLM text trunk with M-RoPE; vision frontend stubbed
[arXiv:2409.12191; hf]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    source="arXiv:2409.12191; hf (verified)",
    n_layers=28, d_model=1536, n_heads=12, n_kv=2, d_ff=8960,
    vocab=151936, head_dim=128, act="silu",
    rope_theta=1_000_000.0, mrope_sections=(16, 24, 24),
    tie_embeddings=True, norm_eps=1e-6,
    frontend="vision", n_img_tokens=256,
    strategy="fsdp_cp",              # 12 heads ∤ 16
    remat="full",
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=160, vocab=512,
    head_dim=32, mrope_sections=(4, 6, 6), n_img_tokens=8,
    param_dtype="float32", compute_dtype="float32", remat="none",
    loss_chunk=64,
)

register("qwen2-vl-2b", CONFIG, REDUCED)
