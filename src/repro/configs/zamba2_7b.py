"""zamba2-7b — Mamba2 backbone + shared attention block
[arXiv:2411.15242; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    source="arXiv:2411.15242 (unverified tier)",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, d_ff=14336,
    vocab=32000, head_dim=112, act="silu",
    ssm_state=64, d_inner=7168, ssm_head_dim=64, conv_width=4,
    hybrid_period=6,                 # 81 = 13×(5 mamba + shared attn) + 3
    rope_theta=10_000.0, norm_eps=1e-5,
    strategy="tp",                   # attn 32 heads | 16; 112 ssm heads | 16
    remat="full",
)

REDUCED = CONFIG.replace(
    n_layers=7, d_model=64, n_heads=4, n_kv=4, d_ff=160, vocab=512,
    head_dim=16, ssm_state=16, d_inner=128, ssm_head_dim=32,
    hybrid_period=3,
    param_dtype="float32", compute_dtype="float32", remat="none",
    loss_chunk=64,
)

register("zamba2-7b", CONFIG, REDUCED)
