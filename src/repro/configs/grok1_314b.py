"""grok-1-314b — 8-expert top-2 MoE, attention logit softcap
[hf:xai-org/grok-1; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    source="hf:xai-org/grok-1 (unverified tier)",
    n_layers=64, d_model=6144, n_heads=48, n_kv=8, d_ff=32768,
    vocab=131072, head_dim=128, act="gelu",
    n_experts=8, top_k=2, capacity_factor=1.25,
    attn_softcap=30.0,
    rope_theta=10_000.0, norm_eps=1e-5,
    strategy="tp",                   # 48 heads | 16
    remat="nested", microbatches=4, opt_state_dtype="int8",
)

REDUCED = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv=2, d_ff=192, vocab=512,
    head_dim=16, n_experts=4, top_k=2,
    param_dtype="float32", compute_dtype="float32",
    remat="none", microbatches=1, opt_state_dtype="float32", loss_chunk=64,
)

register("grok-1-314b", CONFIG, REDUCED)
