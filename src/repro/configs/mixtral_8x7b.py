"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    source="arXiv:2401.04088; hf (verified)",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=32000, head_dim=128, act="silu",
    n_experts=8, top_k=2, capacity_factor=1.25,
    window=4096,                     # SWA → rolling KV ring buffer
    rope_theta=1_000_000.0, norm_eps=1e-5,
    strategy="tp", remat="full",
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=160, vocab=512,
    head_dim=16, n_experts=4, top_k=2, window=16,
    param_dtype="float32", compute_dtype="float32", remat="none",
    loss_chunk=64,
)

register("mixtral-8x7b", CONFIG, REDUCED)
