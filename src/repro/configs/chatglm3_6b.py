"""chatglm3-6b — dense GQA(kv=2), RoPE on half the head dims
[arXiv:2406.12793; hf]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    source="arXiv:2406.12793; hf (verified)",
    n_layers=28, d_model=4096, n_heads=32, n_kv=2, d_ff=13696,
    vocab=65024, head_dim=128, act="silu",
    rope_theta=10_000.0, rotary_pct=0.5,   # "RoPE 2d": rotary on half dims
    norm_eps=1e-5, strategy="tp", remat="full",
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=160, vocab=512,
    head_dim=16, param_dtype="float32", compute_dtype="float32",
    remat="none", loss_chunk=64,
)

register("chatglm3-6b", CONFIG, REDUCED)
