"""gemma3-1b — 5:1 local:global attention, 256k vocab, head_dim 256
[hf:google/gemma-3-1b-pt; unverified]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    source="hf:google/gemma-3-1b-pt (unverified tier)",
    n_layers=26, d_model=1152, n_heads=4, n_kv=1, d_ff=6912,
    vocab=262144, head_dim=256, act="gelu",
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    window=512, local_global_period=6,     # 5 local : 1 global
    qk_norm=True, sandwich_norm=True, embed_scale=True,
    tie_embeddings=True, norm_eps=1e-6,
    strategy="fsdp_cp",              # 4 heads ∤ 16
    remat="full",
)

REDUCED = CONFIG.replace(
    n_layers=6, d_model=64, n_heads=4, n_kv=1, d_ff=160, vocab=512,
    head_dim=16, window=8, local_global_period=3,
    param_dtype="float32", compute_dtype="float32", remat="none",
    loss_chunk=64,
)

register("gemma3-1b", CONFIG, REDUCED)
