"""deepseek-coder-33b — dense llama-arch GQA [arXiv:2401.14196; hf]."""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    source="arXiv:2401.14196; hf (verified)",
    n_layers=62, d_model=7168, n_heads=56, n_kv=8, d_ff=19200,
    vocab=32256, head_dim=128, act="silu",
    rope_theta=100_000.0, norm_eps=1e-6,
    strategy="fsdp_cp",            # 56 heads ∤ 16 → context-parallel attention
    remat="nested", microbatches=1,
    notes="llama-style trunk; CP attention because 56 % 16 != 0",
)

REDUCED = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=160, vocab=512,
    head_dim=16, param_dtype="float32", compute_dtype="float32",
    remat="none", loss_chunk=64,
)

register("deepseek-coder-33b", CONFIG, REDUCED)
