"""Loop-aware cost model over compiled HLO text.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) counts
every computation ONCE — a ``lax.scan`` over 126 layers contributes a
single layer's FLOPs.  Since this framework scans everything (layers,
microbatches, attention chunks), those numbers undercount by orders of
magnitude.  This module re-derives loop-aware totals from the optimized
HLO text itself:

* ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` —
  body/condition costs are multiplied through (nested loops compose);
* ``dot`` FLOPs = 2 · |out| · (contracted lhs dims), with operand shapes
  resolved from the per-computation symbol table;
* memory bytes are counted at instruction *boundaries* (operands+outputs
  of top-level ops; fusion interiors are skipped — a reasonable stand-in
  for fused HBM traffic);
* collective wire bytes use a ring model: all-gather ≈ out·(g−1)/g,
  all-reduce ≈ 2·out·(g−1)/g, reduce-scatter ≈ out·(g−1), all-to-all ≈
  out·(g−1)/g, collective-permute ≈ out — with the replica-group size g
  parsed per op, and loop multipliers applied (a per-layer all-gather in
  a 126-layer scan counts 126×).

Everything here is per-DEVICE (the module is the SPMD-partitioned one).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.roofline import hw

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_SHAPE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([a-z][\w\-]*)\((.*)$")
_PARAM = re.compile(r"([\w.\-]+):\s*([a-z]+\d*\[[\d,]*\])")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"(?:to_apply|condition|body)=%?([\w.\-]+)")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELTWISE_TRANSCENDENTAL = {"exponential", "tanh", "log", "rsqrt", "sqrt",
                           "power", "logistic", "sine", "cosine"}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * hw.DTYPE_BYTES.get(dtype, 0)


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Instr:
    name: str
    out_shapes: list            # [(dtype, dims_str)]
    opcode: str
    rest: str                   # everything after the opening paren
    line: str


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.coll_bytes * m,
                    {k: v * m for k, v in self.coll_by_op.items()})


def parse_computations(text: str) -> tuple[dict, str]:
    """→ ({comp_name: (instrs, param_shapes)}, entry_name)."""
    comps: dict[str, tuple[list[Instr], dict]] = {}
    entry = None
    cur: list[Instr] | None = None
    cur_params: dict | None = None
    cur_name = None
    for raw in text.splitlines():
        m = _COMP_HDR.match(raw)
        if m:
            cur_name = m.group(2)
            cur = []
            cur_params = {}
            for pname, ptype in _PARAM.findall(m.group(3)):
                sm = _SHAPE.findall(ptype)
                if sm:
                    cur_params[pname] = sm[0]
            comps[cur_name] = (cur, cur_params)
            if m.group(1):
                entry = cur_name
            continue
        if raw.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR.match(raw)
        if not im:
            continue
        name, out_t, opcode, rest = im.groups()
        cur.append(Instr(name, _SHAPE.findall(out_t), opcode, rest, raw))
    return comps, entry or "main"


def _group_size(line: str, n_partitions: int) -> int:
    m = _GROUPS_LIST.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    m = _GROUPS_IOTA.search(line)
    if m:
        return max(1, int(m.group(2)))
    return max(1, n_partitions)


def _wire_bytes(op: str, out_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return out_bytes * (g - 1) / g
    if op == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return float(out_bytes) * (g - 1)
    if op == "all-to-all":
        return out_bytes * (g - 1) / g
    return float(out_bytes)  # collective-permute


class HloCost:
    def __init__(self, text: str, n_partitions: int = 1):
        self.comps, self.entry = parse_computations(text)
        self.n_partitions = n_partitions
        self._memo: dict[str, Cost] = {}

    # ---- per-instruction ----------------------------------------------------

    def _sym(self, comp_name: str) -> dict:
        instrs, params = self.comps[comp_name]
        table = dict(params)
        for i in instrs:
            if i.out_shapes:
                table[i.name] = i.out_shapes[0]
            # tuple-typed: keep all for gte? gte lines carry own types.
        return table

    def _producers(self, comp_name: str) -> dict:
        return {i.name: i for i in self.comps[comp_name][0]}

    def _is_legalized_bf16(self, comp_name: str, i: Instr, sym: dict) -> bool:
        """True when a collective's f32 operand is a CPU-legalization
        upconvert of a bf16 value (the CPU backend has no native bf16 and
        float-normalizes before collectives; on the TPU target these ops
        move bf16).  Detected by a convert-producer whose source is bf16."""
        if not i.out_shapes or i.out_shapes[0][0] != "f32":
            return False
        prods = self._producers(comp_name)
        paren = i.rest.split(")")[0]
        for ref in re.findall(r"%([\w.\-]+)", paren):
            p = prods.get(ref)
            if p is None:
                continue
            looks_convert = (p.opcode == "convert"
                             or "convert" in p.name
                             or (p.opcode == "fusion" and "convert" in p.line))
            if looks_convert and "bf16[" in p.line:
                return True
            # one more hop through copies
            if p.opcode in ("copy", "bitcast") :
                inner = re.findall(r"%([\w.\-]+)", p.rest.split(")")[0])
                for r2 in inner:
                    p2 = prods.get(r2)
                    if p2 is not None and ("convert" in p2.name
                                           or p2.opcode == "convert") \
                            and "bf16[" in p2.line:
                        return True
        return False

    def _instr_cost(self, comp_name: str, i: Instr, sym: dict) -> Cost:
        c = Cost()
        op = i.opcode
        out_b = sum(_shape_bytes(dt, dd) for dt, dd in i.out_shapes)
        out_e = sum(_shape_elems(dd) for _, dd in i.out_shapes)

        # ---- called computations ------------------------------------------
        if op == "while":
            trip = 1
            tm = _TRIP.search(i.line)
            if tm:
                trip = int(tm.group(1))
            for sub in _TO_APPLY.findall(i.line):
                c += self.cost_of(sub).scaled(trip)
            return c
        if op == "fusion":
            cm = _CALLS.search(i.line)
            if cm:
                sub = self.cost_of(cm.group(1))
                c.flops += sub.flops          # interior bytes skipped (fused)
                c.coll_bytes += sub.coll_bytes
            c.bytes += out_b + self._operand_bytes(i, sym)
            return c
        if op in ("call", "async-start", "custom-call"):
            cm = _CALLS.search(i.line) or _TO_APPLY.search(i.line)
            if cm:
                c += self.cost_of(cm.group(1))
            c.bytes += out_b + self._operand_bytes(i, sym)
            return c
        if op == "conditional":
            subs = [self.cost_of(s) for s in _TO_APPLY.findall(i.line)]
            if subs:
                best = max(subs, key=lambda s: s.flops)
                c += best
            return c

        # ---- collectives -----------------------------------------------------
        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in _COLLECTIVES:
            if op.endswith("-done"):
                return c
            g = _group_size(i.line, self.n_partitions)
            eff_b = out_b
            if self._is_legalized_bf16(comp_name, i, sym):
                eff_b = out_b // 2          # TPU target moves bf16, not f32
            w = _wire_bytes(base_op, eff_b, g)
            c.coll_bytes += w
            c.coll_by_op[base_op] = c.coll_by_op.get(base_op, 0.0) + w
            c.bytes += eff_b
            return c

        # ---- compute ---------------------------------------------------------
        if op == "dot":
            # contraction size from lhs shape + lhs_contracting_dims.  Newer
            # XLA prints operands with inline types (``dot(f32[64,256]{1,0}
            # %x, ...)``) — take the first inline shape; older text prints
            # bare ``%x`` refs — fall back to the symbol table.
            paren = i.rest.split(")")[0]
            inline = _SHAPE.findall(paren)
            if inline:
                lhs = inline[0]
            else:
                lm = re.search(r"%([\w.\-]+)", paren)
                lhs = sym.get(lm.group(1)) if lm else None
            kdim = 1
            mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", i.line)
            if lhs and mm and mm.group(1):
                dims = lhs[1].split(",") if lhs[1] else []
                for idx in mm.group(1).split(","):
                    ii = int(idx)
                    if ii < len(dims):
                        kdim *= int(dims[ii])
            # batch dims are part of out; contraction covers the rest
            c.flops += 2.0 * out_e * kdim
        elif op == "convolution":
            c.flops += 2.0 * out_e  # lower bound; no convs on our hot paths
        elif op in _ELTWISE_TRANSCENDENTAL:
            c.flops += float(out_e)
        elif op in ("add", "multiply", "subtract", "divide", "maximum",
                    "minimum", "compare", "select"):
            c.flops += float(out_e)

        if op not in ("parameter", "get-tuple-element", "tuple", "bitcast",
                      "constant"):
            c.bytes += out_b + self._operand_bytes(i, sym)
        return c

    def _operand_bytes(self, i: Instr, sym: dict) -> int:
        total = 0
        paren = i.rest.split(")")[0]
        for ref in re.findall(r"%([\w.\-]+)", paren):
            sh = sym.get(ref)
            if sh:
                total += _shape_bytes(sh[0], sh[1])
        return total

    # ---- per-computation -----------------------------------------------------

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        if comp_name not in self.comps:
            return Cost()
        self._memo[comp_name] = Cost()  # cycle guard
        sym = self._sym(comp_name)
        total = Cost()
        for i in self.comps[comp_name][0]:
            total += self._instr_cost(comp_name, i, sym)
        self._memo[comp_name] = total
        return total

    def total(self) -> Cost:
        return self.cost_of(self.entry)
