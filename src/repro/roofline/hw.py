"""TPU v5e hardware constants (the roofline denominators)."""

PEAK_FLOPS_BF16 = 197e12       # per chip, bf16
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (~ per chip, ring)
CHIPS_PER_POD = 256
HBM_BYTES = 16 * 2**30         # 16 GiB per chip

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}
