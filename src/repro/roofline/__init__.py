"""repro.roofline — three-term roofline from compiled dry-run artifacts."""

from repro.roofline.analysis import Roofline, analyze, collective_bytes, model_flops

__all__ = ["Roofline", "analyze", "collective_bytes", "model_flops"]
