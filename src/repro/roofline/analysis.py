"""Roofline-term extraction from a compiled (dry-run) artifact.

Three terms per (arch × shape × mesh), all in seconds (DESIGN.md §8):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_operand_bytes_per_device / ICI_BW

``cost_analysis`` yields per-device FLOPs/bytes for the SPMD-partitioned
module; collective bytes are parsed from the optimized HLO text (XLA does
not report them in cost_analysis).  The dominant term is the bottleneck
the §Perf loop iterates on; ``MODEL_FLOPS / HLO_FLOPs`` flags
remat/replication waste.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.roofline import hw

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# dtype[dims]{layout} or dtype[dims] tokens, e.g. bf16[16,512]{1,0}
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\)|[a-z0-9\[\],{}:\s]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _token_bytes(dtype: str, dims: str) -> int:
    if dtype not in hw.DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * hw.DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-opcode operand bytes summed over the module (per-device)."""
    out: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # avoid double counting start/done async pairs
        op = m.group(1)
        # operands are inside the call parens; output type precedes the op
        try:
            args = line.split(m.group(0)[-len(op) - 1:], 1)[1]
        except Exception:
            args = line
        paren = args[args.find("(") + 1: args.rfind(")")] if "(" in args else args
        toks = _SHAPE_RE.findall(paren)
        if not toks:  # fall back to the output type (lhs of '=')
            toks = _SHAPE_RE.findall(line.split("=", 1)[0])
        out[op] += sum(_token_bytes(dt, dims) for dt, dims in toks)
    return out


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    chips: int
    coll_breakdown: dict = field(default_factory=dict)
    raw_cost_analysis: dict = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / hw.ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "coll_breakdown": self.coll_breakdown,
            "raw_cost_analysis": self.raw_cost_analysis,
        }


def analyze(compiled, chips: int) -> Roofline:
    """Loop-aware totals from the optimized HLO (see hlo_cost.py) —
    ``compiled.cost_analysis()`` counts scan bodies once, so its raw
    numbers are kept only as a reference field."""
    from repro.roofline.hlo_cost import HloCost

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returned [dict]
        cost = cost[0]
    text = compiled.as_text()
    hc = HloCost(text, n_partitions=chips)
    tot = hc.total()
    roof = Roofline(
        flops_per_device=tot.flops,
        bytes_per_device=tot.bytes,
        coll_bytes_per_device=tot.coll_bytes,
        chips=chips,
        coll_breakdown=dict(tot.coll_by_op),
    )
    roof.raw_cost_analysis = {
        "flops_once": float(cost.get("flops", 0.0)),
        "bytes_once": float(cost.get("bytes accessed", 0.0)),
    }
    return roof


def model_flops(cfg, shape, steps: int = 1) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) training FLOPs for the cell;
    forward-only kinds use 2·N·D."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens * steps
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens * steps
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active * tokens * steps
